"""Unbiased compression operators (assumption A4) and the partial-
participation composition of Lemma 1 (Appendix D.2).

Every operator is a pair (compress_fn, omega) with

    E[Quant(s)] = s,      E[||Quant(s) - s||^2] <= omega ||s||^2.

Operators act leaf-wise on pytrees and fold the RNG key per leaf.

This module is the ONE compression subsystem of the repo: the reference
Algorithm 2 (``core/fedmm.py``), the transformer-scale trainer
(``fed/trainer.py``), the benchmarks, and the tests all route through the
``Compressor`` objects built here. The stochastic-rounding block quantizer
has exactly one rounding semantics, defined by the pure-jnp oracle
``kernels/ref.py:quantize_groups_ref``; ``quantize_leaf`` below dispatches

  * large leaves (>= ``KERNEL_DISPATCH_MIN`` elements with a 128-aligned
    group — ANY rank: multi-dim leaves collapse their leading dims to rows
    while the grouped last axis stays intact) to the Pallas kernels in
    ``kernels/quantize_block.py`` via ``kernels/ops.py`` (interpret mode on
    CPU, compiled Mosaic on TPU), and
  * everything else to the jnp oracle — in shard_safe mode applied
    group-wise along the LAST axis only, an elementwise-fusable graph that
    preserves GSPMD sharding. (The kernel's leading-dim collapse keeps the
    last axis — the 'model'-sharded one — intact; on a sharded mesh the
    pallas_call itself still needs a shard_map wrapper, so multi-host
    sharded leaves should keep the jnp path.)

Grouping has two modes behind ``shard_safe=``:

  * ``shard_safe=False`` (default — the paper's block-p quantizer, used by
    the reference Algorithm 2 and the figures): each leaf is flattened and
    padded to full ``block``-sized groups, so every leaf is genuinely
    quantized at the requested block size;
  * ``shard_safe=True`` (the trainer at transformer scale): groups stay
    along the LAST axis with size ``group_size(D, block)`` — the largest
    power-of-2 that divides the per-shard width under worst-case 32-way
    sharding. Leaves whose last dim yields g == 1 pass through unquantized
    (and are billed at their dtype by ``payload_bytes``).

The stochastic-rounding dither comes from one of three sources behind the
``dither=`` flag:

  * ``"uniform"`` — ``jax.random.uniform`` (threefry; statistically clean,
    but several u32 intermediates per element on parameter-sized tensors);
  * ``"hash"``    — a fused murmur3-finalizer hash of the element index and
    the folded key, producing 24-bit-resolution uniforms in [0, 1). Zero
    extra memory; the trainer's default at scale.
  * ``"kernel"``  — OPT-IN: the dither is generated INSIDE the Pallas
    kernel (2 instead of 3 HBM arrays per element). On real TPU the draws
    come from the hardware PRNG (``pltpu.prng_seed``/``prng_random_bits``
    seeded from the folded key + grid position) and therefore DIFFER from
    the streamed sources — this mode is never golden-pinned. In interpret
    mode (CPU validation) the kernel evaluates the same murmur hash as
    ``"hash"`` in-kernel, so CPU draws match ``"hash"`` exactly. Leaves
    that do not dispatch to the kernel fall back to ``"hash"``.

Both streamed paths compare the dither against the round-up fraction in
float32 (24-bit resolution), so the quantizer is unbiased to ~2^-24 per
element — see ``tests/test_compression_unified.py`` for the 1/sqrt(trials)
check.

Compute dtype is a third axis behind ``compute=``: ``"f32"`` (default) is
the oracle semantics — the whole chain in float32, bit-identical to the
Pallas kernel; ``"native"`` keeps everything except the dither comparison
in the input dtype (the ROADMAP bf16 path: half the transient HBM on
parameter-sized bf16 chains, codes within ±1 level of the oracle on the
~2^-8-measure bf16 ratio-rounding boundary — see
``kernels/ref.py:quantize_groups_native``).

Wire format (the PACKED low-bit uplink; see src/repro/api/README.md)
--------------------------------------------------------------------
``block_quant`` compressors additionally expose an ``encode``/``decode``
pair with a REAL wire format: per leaf, a ``PackedLeaf`` of

  * ``codes``  — the integer quantization codes: int8 (1 byte/coord) for
    4 < bits <= 8, bit-packed two-per-byte uint8 (0.5 bytes/coord) for
    bits <= 4 (adjacent pairs along the code stream's last axis);
  * ``scales`` — one scale per quantization group, float32 under the
    oracle semantics (input dtype under ``compute="native"``).

``decode(encode(key, tree))`` is BIT-IDENTICAL to ``apply(key, tree)``
(same draws, same dispatch, same arithmetic order — the int8/nibble
round-trip of the integer codes is exact), so the federated golden
trajectories are unchanged when drivers aggregate in code space.
``payload_bytes`` counts EXACTLY the bytes of those buffers (codes +
scales, including flat-mode pad), and ``encoded_bytes``/``wire_bytes``
measure the same number off an actual payload / eval_shape.

``decode_reduce_tree`` is the server side of the driver's fused
``uplink="reduce"`` collective: the mu-weighted sum over a stacked
C-client payload with dequantize fused into the accumulation (the Pallas
``decode_reduce_grouped_pallas`` kernel for large aligned leaves — the
decoded f32 client stack never materializes; jnp decode + tensordot,
bit-identical to decode-then-reduce, everywhere else).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..kernels import ops as kernel_ops
from ..kernels import ref as kernel_ref

Pytree = object

# Leaves at least this large (with a 128-aligned group) go to the Pallas
# kernel.
KERNEL_DISPATCH_MIN = 1 << 16

# at or below this code width, two codes travel per byte
PACK_BITS = 4

DITHERS = ("hash", "uniform", "kernel")


# ---------------------------------------------------------------------------
# the wire format
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackedLeaf:
    """One leaf's uplink payload: packed codes + per-group scales.

    codes: int8 ``(..., D)`` (shard mode) / ``(padded,)`` (flat mode), or
    uint8 with half the last dim when bit-packed (bits <= 4). scales: one
    per group — ``(..., D // g)`` shard / ``(n_blocks,)`` flat. ``check``
    is the optional wire-integrity checksum: one uint32 per payload (a
    position-weighted murmur-mixed digest of the codes AND scales
    buffers, ``leaf_checksum``), computed by the sender at encode time
    and verified by ``verify_payload`` at decode — ``None`` for
    compressors built without ``checksum=True``. The remaining fields
    are static pytree metadata (shape/dtype of the original leaf, code
    width, group size, grouping mode), so ``vmap`` batches the buffers
    and leaves the layout alone."""
    codes: Pytree
    scales: Pytree
    shape: tuple
    dtype: str
    bits: int
    group: int
    mode: str  # "shard" | "flat"
    check: Pytree = None  # uint32 digest (stacked under vmap) | None


jax.tree_util.register_dataclass(
    PackedLeaf, data_fields=("codes", "scales", "check"),
    meta_fields=("shape", "dtype", "bits", "group", "mode"))


def pack_nibbles(codes):
    """int8 codes in [-8, 7], even last dim -> uint8 with adjacent pairs in
    one byte (low nibble = even index, high nibble = odd index)."""
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return ((lo & 0x0F) | ((hi & 0x0F) << 4)).astype(jnp.uint8)


def unpack_nibbles(packed):
    """Exact inverse of ``pack_nibbles`` (arithmetic-shift sign extension)."""
    b = packed.astype(jnp.int8)
    lo = jnp.left_shift(b, 4) >> 4
    hi = b >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))


def _maybe_pack(codes, bits: int):
    if bits <= PACK_BITS and codes.shape[-1] % 2 == 0:
        return pack_nibbles(codes)
    return codes


def _tree_bytes(tree) -> int:
    """Actual buffer bytes of a pytree (arrays or ShapeDtypeStructs)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = getattr(leaf, "shape", ())
        n = int(math.prod(shape)) if shape else 1
        total += n * jnp.dtype(getattr(leaf, "dtype", jnp.float32)).itemsize
    return total


# ---------------------------------------------------------------------------
# wire integrity: per-leaf checksums on the packed payload
# ---------------------------------------------------------------------------

# one uint32 digest per PackedLeaf on the wire
CHECKSUM_BYTES = 4

_CKSUM_GOLDEN = 0x9E3779B9   # position salt (golden-ratio odd constant)
_CKSUM_SCALE_SALT = 0x85EBCA6B  # domain separation: scales vs codes stream


def _mix32(u):
    """murmur3 finalizer on uint32 — the same mixer ``hash_dither`` uses,
    applied per element so ANY single-element change flips the digest
    term (modular-sum collisions are the 2^-32 birthday bound, not a
    structured weakness like a plain sum's swap-invariance)."""
    u = (u ^ (u >> 16)) * jnp.uint32(0x7FEB352D)
    u = (u ^ (u >> 15)) * jnp.uint32(0x846CA68B)
    return u ^ (u >> 16)


def _as_u32_stream(buf, n_batch: int):
    """Bitcast any codes/scales buffer to a ``batch + (m,)`` uint32 view
    (value-preserving per element: int8/uint8 widen, f32 bitcasts, bf16
    bitcasts to u16 then widens)."""
    dt = jnp.dtype(buf.dtype)
    if dt == jnp.float32:
        u = jax.lax.bitcast_convert_type(buf, jnp.uint32)
    elif dt.kind == "f":
        # sub-f32 floats (bf16/f16): bitcast to the same-width uint, widen
        u = jax.lax.bitcast_convert_type(
            buf, jnp.dtype(f"uint{dt.itemsize * 8}")).astype(jnp.uint32)
    else:
        # int8 codes widen through int32 (sign-extended, deterministic)
        u = buf.astype(jnp.int32).astype(jnp.uint32)
    batch = buf.shape[:n_batch]
    return u.reshape(batch + (-1,))


def _digest(buf, n_batch: int, salt: int):
    u = _as_u32_stream(buf, n_batch)
    pos = jax.lax.broadcasted_iota(jnp.uint32, u.shape, u.ndim - 1)
    terms = _mix32(u + pos * jnp.uint32(_CKSUM_GOLDEN) + jnp.uint32(salt))
    # uint32 sum wraps mod 2^32 — order-independent, so the stacked
    # (batched) recompute at verify time matches the per-client encode
    return jnp.sum(terms, axis=-1, dtype=jnp.uint32)


def leaf_checksum(codes, scales, n_batch: int = 0):
    """The wire digest of one payload leaf's buffers: position-weighted
    murmur-mixed uint32 sum over the codes stream and the (domain-
    separated) scales stream. ``n_batch`` leading axes are treated as
    batch dims — one digest per batch row — so the same function computes
    the sender digest (``n_batch=0``, inside the per-client vmap) and the
    receiver recompute on a stacked n-client payload (``n_batch=1``)."""
    return (_digest(codes, n_batch, 0)
            + _digest(scales, n_batch, _CKSUM_SCALE_SALT))


def payload_batch_dims(p: "PackedLeaf") -> int:
    """How many leading axes of ``p.codes`` are client/batch stacking on
    top of the recorded wire layout (the convention ``decode_leaf`` uses:
    shard mode keeps the leaf's rank, flat mode is a 1-D stream)."""
    base = len(p.shape) if p.mode == "shard" else 1
    return p.codes.ndim - base


def verify_leaf(p):
    """Recompute one leaf's digest and compare to the wire checksum.
    Returns a bool array over the leaf's batch dims (scalar True for
    unbatched / unchecksummed / raw leaves)."""
    if not isinstance(p, PackedLeaf) or p.check is None:
        return jnp.bool_(True)
    nb = payload_batch_dims(p)
    return jnp.equal(leaf_checksum(p.codes, p.scales, nb), p.check)


def verify_payload(payload):
    """Per-client wire verification of a (possibly stacked) payload:
    AND of every checksummed leaf's digest match, broadcast over the
    batch dims — ``ok[c] == True`` iff EVERY leaf of client c's payload
    arrived intact. Scalar True when nothing carries a checksum."""
    ok = jnp.bool_(True)
    for leaf in jax.tree.leaves(
            payload, is_leaf=_is_payload_leaf):
        ok = jnp.logical_and(ok, verify_leaf(leaf))
    return ok


def zero_invalid_rows(payload, ok):
    """Null out every buffer row of clients that failed verification
    (``ok`` broadcastable over each buffer's leading batch axes), BEFORE
    decode: corrupted scale bits can decode to NaN/inf, and a NaN times a
    zero weight is NaN — the poison would survive any masked reduction.
    Zero codes x zero scales decode to exact zeros, so a dropped client
    contributes nothing on every downstream path (decode, decode_reduce,
    variate updates)."""
    okb = jnp.asarray(ok, jnp.bool_)

    def _zero(buf):
        sel = okb.reshape(okb.shape + (1,) * (buf.ndim - okb.ndim))
        return jnp.where(sel, buf, jnp.zeros((), buf.dtype))

    def leaf(p):
        if not isinstance(p, PackedLeaf):
            return p
        return dataclasses.replace(
            p, codes=_zero(p.codes), scales=_zero(p.scales),
            check=None if p.check is None else _zero(p.check))

    return jax.tree.map(leaf, payload, is_leaf=_is_payload_leaf)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """An unbiased compressor satisfying A4(omega), with communication
    accounting (payload bytes per uplink, effective omega under Lemma 1).

    ``apply`` is the fused quantize->dequantize operator (what legacy
    callers see). Compressors with a real wire format also carry
    ``encode`` (-> pytree with ``PackedLeaf`` leaves; unquantized leaves
    pass through raw) and ``decode`` (its exact inverse up to quantization:
    ``decode . encode == apply`` bit-for-bit). ``decode`` accepts stacked
    payloads (extra leading axes on the buffers) so servers can aggregate
    straight off an n-client payload stack."""

    apply: Callable  # (key, pytree) -> pytree
    omega: float     # relative variance bound
    bits: float      # payload bits per coordinate (for communication accounting)
    name: str = "compressor"
    # per-leaf payload model: (shape, itemsize) -> bytes on the wire
    # (None -> bits/8 * n)
    payload_fn: Optional[Callable] = None
    encode: Optional[Callable] = None  # (key, pytree) -> payload pytree
    decode: Optional[Callable] = None  # payload pytree -> pytree
    # (payload, w, fused=None) -> weighted partial aggregate in the
    # accumulation dtype: the server side of the driver's fused
    # ``uplink="reduce"`` stage, carrying this compressor's OWN kernel
    # dispatch policy (threshold, alignment) — see ``decode_reduce_tree``
    decode_reduce: Optional[Callable] = None
    # encode stamps each PackedLeaf with its wire digest (CHECKSUM_BYTES
    # per leaf, billed in payload_fn) and the server verifies at decode
    checksum: bool = False
    # (key, partial pytree) -> payload pytree: re-enter the wire format at
    # a topology tier boundary (requantize the f32 edge partial before it
    # crosses the backbone). Stamps FRESH digests — each tier's hop is
    # independently verifiable. None for compressors without a wire format.
    reencode: Optional[Callable] = None

    def __call__(self, key, s):
        return self.apply(key, s)

    def _leaf_payload(self, shape, itemsize: float = 4.0) -> float:
        n = float(math.prod(shape)) if shape else 1.0
        if self.payload_fn is not None:
            return float(self.payload_fn(tuple(shape), float(itemsize)))
        return n * self.bits / 8.0

    def payload_bytes(self, tree) -> float:
        """Uplink bytes for one client's payload of ``tree``'s shape.
        Accepts arrays or ShapeDtypeStructs (shape + dtype are read, so
        uncompressed bf16 leaves bill 2 bytes/coord, not 4). For wire-format
        compressors this equals the ACTUAL encoded buffer bytes —
        ``tests/test_wire_format.py`` pins it against ``encoded_bytes``."""
        total = 0.0
        for leaf in jax.tree.leaves(tree):
            shape = getattr(leaf, "shape", ())
            dt = getattr(leaf, "dtype", None)
            itemsize = float(jnp.dtype(dt).itemsize) if dt is not None else 4.0
            total += self._leaf_payload(shape, itemsize)
        return total

    def encoded_bytes(self, payload) -> int:
        """Actual wire bytes of one encoded payload (codes + scales buffers,
        raw passthrough leaves at their dtype)."""
        return _tree_bytes(payload)

    def wire_bytes(self, tree) -> float:
        """Exact uplink bytes for one client, measured off the encoded
        buffers via ``eval_shape`` (no FLOPs); falls back to the analytic
        ``payload_bytes`` model for compressors without a wire format."""
        if self.encode is None:
            return self.payload_bytes(tree)
        structs = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
        payload = jax.eval_shape(self.encode, jax.random.PRNGKey(0), structs)
        return float(self.encoded_bytes(payload))

    def round_metrics(self, tree, p: float = 1.0) -> dict:
        """Static per-round accounting: payload per client, A4 variance
        bound, and the Lemma-1 effective bound under participation p."""
        return {
            "payload_bytes_per_client": self.payload_bytes(tree),
            "omega": self.omega,
            "omega_eff": effective_omega(self.omega, p),
        }


def _tree_keyed_map(fn, key, tree):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [fn(k, x) for k, x in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# Identity (omega = 0)
# ---------------------------------------------------------------------------

def identity() -> Compressor:
    return Compressor(
        apply=lambda key, s: s, omega=0.0, bits=32.0, name="identity",
        payload_fn=lambda shape, itemsize:
            (float(math.prod(shape)) if shape else 1.0) * itemsize)


# ---------------------------------------------------------------------------
# Stochastic uniform quantization in blocks (block-p quantization of
# Dieuleveut et al. 2021, Supp. B; QSGD-style): per group of size g along the
# last axis, scale = max|x|, stochastic-round x/scale to 2^(b-1) levels.
# A4 bound: per-coord Var <= (scale/levels)^2 / 4 and scale^2 <= ||group||^2,
# so E||Q(s)-s||^2 <= g/(4 levels^2) ||s||^2 <= block/(4 levels^2) ||s||^2.
# ---------------------------------------------------------------------------

def group_size(D: int, block: int) -> int:
    """Largest power-of-2 quantization group that divides the per-shard
    width of the last dim (worst case 32-way sharding), capped at ``block``.
    Keeping groups shard-local is what lets GSPMD partition the quantizer —
    a flat reshape across sharded dims would force full rematerialization
    of parameter-sized tensors (observed: 7 TB/device on qwen3-235b)."""
    per = D
    for s in (32, 16):
        if D % s == 0:
            per = D // s
            break
    per = max(per, 1)
    g = 1
    while per % (g * 2) == 0 and g * 2 <= block:
        g *= 2
    return g


def fold_seed(key):
    """The int32 scalar seed of the folded key — the SAME derivation
    ``hash_dither`` uses (kd[0] ^ kd[-1]), handed to the in-kernel dither
    so interpret-mode kernel draws replicate the streamed hash draws."""
    kd = jax.random.key_data(key).astype(jnp.uint32)
    return (kd.reshape(-1)[0] ^ kd.reshape(-1)[-1]).astype(jnp.int32)


def hash_dither(key, shape):
    """Stochastic-rounding dither: murmur3-style integer hash of the element
    coordinates, seeded by the (folded) JAX key, mapped to float32 uniforms
    in [0, 1) with 24-bit resolution. Elementwise + broadcast only, so it
    fuses into the surrounding quantization chain, costs zero extra HBM, and
    respects sharding (threefry on parameter-sized tensors costs several
    u32/u64 intermediates per element — ~20 GB/device observed)."""
    kd = jax.random.key_data(key).astype(jnp.uint32)
    seed = kd.reshape(-1)[0] ^ kd.reshape(-1)[-1]
    idx = jnp.zeros(shape, jnp.uint32)
    stride = jnp.uint32(1)
    for d in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(jnp.uint32, shape, d) * stride
        stride = stride * jnp.uint32(shape[d])
    x = idx * jnp.uint32(2654435761) + seed
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    # top 24 bits -> [0, 1): exact in f32, so P(u < t) = t +- 2^-24. The old
    # trainer path compared a uint8-truncated threshold instead, which
    # systematically rounded fractions near 1 down (bias up to ~0.4%/elem).
    return (x >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def _make_dither(dither: str, key, shape):
    if dither == "hash":
        return hash_dither(key, shape)
    if dither == "uniform":
        return jax.random.uniform(key, shape, jnp.float32)
    raise ValueError(f"unknown dither source {dither!r} (want 'hash'|"
                     f"'uniform'; 'kernel' is resolved by the dispatcher)")


def _stream_dither(dither: str) -> str:
    """The streamed fallback for leaves that do not reach the kernel:
    'kernel' degrades to 'hash' (zero-memory, same uniform quality)."""
    return "hash" if dither == "kernel" else dither


def _kernel_route(x, g: int, kernel_threshold: int) -> str:
    """One dispatch decision shared by apply and encode (they MUST agree,
    or decode . encode would not be bit-identical to apply). Returns

      * ``"kernel"``    — the direct Pallas path: large leaf, 128-aligned
        group, and the leaf's buffers live on ONE device (unsharded,
        fully replicated, or a single-device process);
      * ``"shard_map"`` — the leaf is genuinely partitioned under a
        ``NamedSharding`` whose per-shard last-axis width keeps whole
        groups: run the kernel per shard via the ``kernels/ops.py``
        shard_map wrappers (shard-safe groups are shard-local by
        construction, so per-shard kernels are bit-identical to the
        global oracle). Only the shard_safe caller honors this — the
        flat (block-p) layout groups across the global element stream,
        which shards do not preserve;
      * ``"jnp"``       — everything else (small/misaligned leaves,
        opaque or group-splitting shardings, and TRACED leaves inside a
        jit on a multi-device process, whose sharding is unknowable at
        trace time — the conservative pre-sharding behavior).

    This replaces the old process-wide ``jax.device_count() > 1`` guard,
    which silently dropped the kernel for every multi-dim leaf on a
    multi-device host even when the leaf was unsharded or fully
    replicated (tests/test_sharded_driver.py pins the regression under
    8 fake CPU devices)."""
    if x.size < kernel_threshold or g % 128 != 0 or g < 2:
        return "jnp"
    # the tracer check is EXPLICIT (not "has no .sharding attribute"):
    # newer jax versions expose abstract shardings on tracers, which must
    # never route to the eager-only shard_map wrapper
    sharding = (None if isinstance(x, jax.core.Tracer)
                else getattr(x, "sharding", None))
    if sharding is None:
        # traced leaf (or ShapeDtypeStruct): sharding unknowable — keep
        # the conservative behavior for multi-dim leaves so a pjit'd
        # caller never pays a GSPMD gather around an unshardable
        # pallas_call
        # repro: allow[RPL001] tracer fallback only — eager leaves above
        if x.ndim > 1 and jax.device_count() > 1:
            return "jnp"
        return "kernel"
    if sharding.is_fully_replicated or len(sharding.device_set) == 1:
        return "kernel"
    if isinstance(sharding, jax.sharding.NamedSharding):
        shard_shape = sharding.shard_shape(tuple(x.shape))
        if shard_shape[-1] % g == 0:
            return "shard_map"
    return "jnp"


def _kernel_eligible(x, g: int, kernel_threshold: int) -> bool:
    """The flat-mode predicate: only the direct single-device kernel path
    (the flat element stream's groups cross shard boundaries, so sharded
    leaves keep the jnp path there)."""
    return _kernel_route(x, g, kernel_threshold) == "kernel"


def _rows_view(x, g: int):
    """The (R, D) kernel view — ONE definition shared with the per-shard
    dispatch (``kernels/ops.py:rows_view``): the row layout is bit-
    identity-critical (it fixes the global dither element stream)."""
    return kernel_ops.rows_view(x, g)


def quantize_leaf(key, x, bits: int = 8, block: int = 256,
                  dither: str = "uniform", shard_safe: bool = False,
                  kernel_threshold: int = KERNEL_DISPATCH_MIN,
                  compute: str = "f32"):
    """Quantize-dequantize ONE array leaf. Single source of truth for the
    repo's stochastic-rounding block quantizer: grouping via ``shard_safe``
    (see module docstring), dither via ``dither=``, math via the kernel
    oracle pair (Pallas for large leaves — any rank — the jnp oracle
    otherwise; bit-identical given the same draws).

    ``compute``:
      * ``"f32"``    (default) — oracle semantics: the whole chain runs in
        float32 regardless of input dtype (bit-identical to the kernel);
      * ``"native"`` — the ROADMAP bf16 compute path: scale/ratio/dequant
        stay in the input dtype, ONLY the dither-vs-fraction comparison is
        f32 (``kernels/ref.py:quantize_groups_native``, which documents the
        ±1-level equivalence tolerance for bf16 ratio rounding). Halves the
        transient HBM on parameter-sized bf16 chains; no-op for f32 inputs.
    """
    if compute not in ("f32", "native"):
        raise ValueError(f"compute={compute!r} (want 'f32'|'native')")
    if dither not in DITHERS:
        raise ValueError(f"dither={dither!r} (want one of {DITHERS})")
    if bits == 0 or x.ndim == 0 or x.size == 0:
        return x
    orig_dtype = x.dtype
    native = compute == "native" and orig_dtype != jnp.float32

    if shard_safe:
        # groups along the last axis only: elementwise-fusable, preserves
        # GSPMD sharding of parameter-sized leaves
        D = x.shape[-1]
        g = group_size(D, block)
        if g < 2:
            return x  # one-element groups reproduce x exactly; skip the work
        if native:
            u = _make_dither(_stream_dither(dither), key, x.shape)
            xg = x.reshape(x.shape[:-1] + (D // g, g))
            deq = kernel_ref.quantize_groups_native(xg, u.reshape(xg.shape),
                                                    bits=bits)
            return deq.reshape(x.shape)
        route = _kernel_route(x, g, kernel_threshold)
        if route == "kernel":
            x2 = _rows_view(x.astype(jnp.float32), g)
            if dither == "kernel":
                out = kernel_ops.quantize_dequantize_kernel_dither(
                    x2, fold_seed(key), bits=bits, group=g)
            else:
                u = _make_dither(dither, key, x.shape)
                out = kernel_ops.quantize_dequantize_grouped(
                    x2, u.reshape(x2.shape), bits=bits, group=g)
            return out.reshape(x.shape).astype(orig_dtype)
        if route == "shard_map":
            # partitioned leaf: one kernel per shard (groups are shard-
            # local). The dither is streamed from GLOBAL element indices,
            # so the draws — and hence the codes — are bit-identical to
            # the unsharded kernel/oracle. ``dither="kernel"`` seeds from
            # grid position, which is not stable under resharding, so it
            # degrades to the streamed hash here like every off-kernel
            # leaf.
            u = _make_dither(_stream_dither(dither), key, x.shape)
            out = kernel_ops.quantize_dequantize_sharded(
                x.astype(jnp.float32), u, bits=bits, group=g,
                sharding=x.sharding)
            return out.astype(orig_dtype)
        u = _make_dither(_stream_dither(dither), key, x.shape)
        xg = x.astype(jnp.float32).reshape(x.shape[:-1] + (D // g, g))
        deq = kernel_ref.quantize_groups_ref(xg, u.reshape(xg.shape),
                                             bits=bits)
        return deq.reshape(x.shape).astype(orig_dtype)

    # reference block-p semantics (Dieuleveut et al. 2021, Supp. B): flat
    # stream padded to full blocks — every leaf quantized at the requested
    # block size (pad entries quantize to 0 and are discarded)
    n = x.size
    pad = (-n) % block
    if native:
        u = _make_dither(_stream_dither(dither), key, (n + pad,))
        flat = x.reshape(-1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        out = kernel_ref.quantize_groups_native(
            flat.reshape(-1, block), u.reshape(-1, block), bits=bits)
        return out.reshape(-1)[:n].reshape(x.shape)
    flat = x.astype(jnp.float32).reshape(-1)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    if _kernel_eligible(x, block, kernel_threshold):
        if dither == "kernel":
            out = kernel_ops.quantize_dequantize_kernel_dither(
                flat.reshape(-1, block), fold_seed(key), bits=bits,
                group=block).reshape(-1)
        else:
            u = _make_dither(dither, key, (n + pad,))
            out = kernel_ops.quantize_dequantize_with_dither(
                flat, u, bits=bits, block=block)
    else:
        u = _make_dither(_stream_dither(dither), key, (n + pad,))
        out = kernel_ref.quantize_block_ref(flat, u, bits=bits, block=block)
    return out[:n].reshape(x.shape).astype(orig_dtype)


def encode_leaf(key, x, bits: int = 8, block: int = 256,
                dither: str = "uniform", shard_safe: bool = False,
                kernel_threshold: int = KERNEL_DISPATCH_MIN,
                compute: str = "f32", checksum: bool = False):
    """Encode ONE leaf to the wire format (``PackedLeaf``), or pass it
    through raw when ``quantize_leaf`` would (bits == 0 / scalar / empty /
    shard-safe g == 1). Draw-for-draw and dispatch-for-dispatch identical
    to ``quantize_leaf`` — ``decode_leaf(encode_leaf(key, x)) ==
    quantize_leaf(key, x)`` bit-exactly (tests/test_wire_format.py).
    ``checksum=True`` stamps the leaf with its wire digest
    (``leaf_checksum`` over the final packed buffers); ``decode`` ignores
    it, so the roundtrip identity is unchanged."""
    if compute not in ("f32", "native"):
        raise ValueError(f"compute={compute!r} (want 'f32'|'native')")
    if dither not in DITHERS:
        raise ValueError(f"dither={dither!r} (want one of {DITHERS})")
    if bits > 8:
        raise ValueError(f"wire format carries <= 8-bit codes, got {bits}")
    if bits == 0 or x.ndim == 0 or x.size == 0:
        return x
    orig_dtype = x.dtype
    native = compute == "native" and orig_dtype != jnp.float32

    if shard_safe:
        D = x.shape[-1]
        g = group_size(D, block)
        if g < 2:
            return x
        route = None if native else _kernel_route(x, g, kernel_threshold)
        if native:
            u = _make_dither(_stream_dither(dither), key, x.shape)
            xg = x.reshape(x.shape[:-1] + (D // g, g))
            codes, scales = kernel_ref.encode_groups_ref(
                xg, u.reshape(xg.shape), bits=bits)
        elif route == "kernel":
            x2 = _rows_view(x.astype(jnp.float32), g)
            if dither == "kernel":
                c2, s2 = kernel_ops.quantize_encode_kernel_dither(
                    x2, fold_seed(key), bits=bits, group=g)
            else:
                u = _make_dither(dither, key, x.shape)
                c2, s2 = kernel_ops.quantize_encode_grouped(
                    x2, u.reshape(x2.shape), bits=bits, group=g)
            codes = c2.reshape(x.shape[:-1] + (D // g, g))
            scales = s2.reshape(x.shape[:-1] + (D // g, 1))
        elif route == "shard_map":
            # per-shard encode kernels; draws streamed from global indices
            # (see quantize_leaf) — codes/scales stay sharded like x
            u = _make_dither(_stream_dither(dither), key, x.shape)
            c2, s2 = kernel_ops.quantize_encode_sharded(
                x.astype(jnp.float32), u, bits=bits, group=g,
                sharding=x.sharding)
            codes = c2.reshape(x.shape[:-1] + (D // g, g))
            scales = s2.reshape(x.shape[:-1] + (D // g, 1))
        else:
            u = _make_dither(_stream_dither(dither), key, x.shape)
            xg = x.astype(jnp.float32).reshape(x.shape[:-1] + (D // g, g))
            codes, scales = kernel_ref.encode_groups_ref(
                xg, u.reshape(xg.shape), bits=bits)
        wire_codes = _maybe_pack(codes.reshape(x.shape), bits)
        wire_scales = scales.reshape(x.shape[:-1] + (D // g,))
        return PackedLeaf(
            codes=wire_codes, scales=wire_scales,
            shape=tuple(x.shape), dtype=str(orig_dtype), bits=bits,
            group=g, mode="shard",
            check=leaf_checksum(wire_codes, wire_scales) if checksum
            else None)

    n = x.size
    pad = (-n) % block
    if native:
        u = _make_dither(_stream_dither(dither), key, (n + pad,))
        flat = x.reshape(-1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        codes, scales = kernel_ref.encode_groups_ref(
            flat.reshape(-1, block), u.reshape(-1, block), bits=bits)
    else:
        flat = x.astype(jnp.float32).reshape(-1)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        if _kernel_eligible(x, block, kernel_threshold):
            if dither == "kernel":
                codes, scales = kernel_ops.quantize_encode_kernel_dither(
                    flat.reshape(-1, block), fold_seed(key), bits=bits,
                    group=block)
            else:
                u = _make_dither(dither, key, (n + pad,))
                codes, scales = kernel_ops.quantize_encode_grouped(
                    flat.reshape(-1, block), u.reshape(-1, block), bits=bits,
                    group=block)
        else:
            u = _make_dither(_stream_dither(dither), key, (n + pad,))
            codes, scales = kernel_ref.encode_groups_ref(
                flat.reshape(-1, block), u.reshape(-1, block), bits=bits)
    wire_codes = _maybe_pack(codes.reshape(-1), bits)
    wire_scales = scales.reshape(-1)
    return PackedLeaf(
        codes=wire_codes, scales=wire_scales,
        shape=tuple(x.shape), dtype=str(orig_dtype), bits=bits,
        group=block, mode="flat",
        check=leaf_checksum(wire_codes, wire_scales) if checksum else None)


def decode_leaf(p):
    """Dequantize one wire-format leaf (raw leaves pass through). Accepts
    stacked payloads: any leading axes on codes/scales beyond the recorded
    layout are treated as batch dims (this is what lets the server decode
    an n-client payload stack without a vmap)."""
    if not isinstance(p, PackedLeaf):
        return p
    bits, g, shape = p.bits, p.group, p.shape
    codes = p.codes
    if codes.dtype == jnp.uint8:
        codes = unpack_nibbles(codes)
    if p.mode == "shard":
        batch = codes.shape[:codes.ndim - len(shape)]
        D = shape[-1]
        cg = codes.reshape(batch + shape[:-1] + (D // g, g))
        sg = p.scales.reshape(batch + shape[:-1] + (D // g, 1))
        deq = kernel_ref.decode_groups_ref(cg, sg, bits=bits)
        out = deq.reshape(batch + shape)
    else:
        batch = codes.shape[:-1]
        n = int(math.prod(shape))
        cg = codes.reshape(batch + (-1, g))
        sg = p.scales.reshape(batch + (p.scales.shape[-1], 1))
        deq = kernel_ref.decode_groups_ref(cg, sg, bits=bits)
        out = deq.reshape(batch + (-1,))[..., :n].reshape(batch + shape)
    return out.astype(jnp.dtype(p.dtype))


def _is_payload_leaf(x) -> bool:
    return isinstance(x, PackedLeaf)


def decode_tree(payload):
    """Decode every wire-format leaf of a payload pytree (stacked or not)."""
    return jax.tree.map(decode_leaf, payload, is_leaf=_is_payload_leaf)


def decode_reduce_leaf(p, w, kernel_threshold: int = KERNEL_DISPATCH_MIN,
                       fused: Optional[bool] = None):
    """Weighted reduction over the leading client axis of ONE stacked
    payload leaf: ``sum_c w[c] * decode(p[c])``, decoding in the same
    pass. Returns the ACCUMULATION dtype (f32 under f32 weights), not the
    leaf dtype — low-precision (bf16) payloads must not round per partial
    when partials are later summed across devices; the caller downcasts
    ONCE after its final reduction (the driver: after the psum).

    ``PackedLeaf`` leaves whose per-client buffer is large enough (>=
    ``kernel_threshold`` elements with a 128-aligned group) dispatch to the
    fused Pallas dequantize+accumulate kernel (``kernels/ops.py:
    dequantize_reduce_grouped``) — the decoded f32 C-client stack never
    materializes; nibble-packed codes unpack to int8 first (1 byte/coord,
    still never the 4-byte f32 stack). Everything else — small/misaligned
    leaves and raw passthrough leaves — decodes via the jnp oracle and
    reduces with a plain tensordot (bit-identical to decode-then-reduce).
    The kernel accumulates sequentially in c, so against the tensordot
    order it agrees to f32 rounding, not bit-for-bit.

    ``fused`` routes the kernel dispatch the same way ``_kernel_route``
    does for apply/encode (the PR-4 lesson: guard per leaf, not by
    convention): ``None`` (default) inspects the codes buffer — eager
    single-device / fully-replicated buffers take the kernel, traced
    leaves on multi-device processes and genuinely partitioned buffers
    keep the conservative jnp path (a pallas_call under GSPMD would force
    a gather of the whole stacked payload). ``True`` asserts the caller
    is already in a per-device (manual / shard_map) context — the
    driver's reduce uplink; ``False`` forces the jnp path."""
    if not isinstance(p, PackedLeaf):
        return jnp.tensordot(w, p, axes=1)
    shape, g, bits = p.shape, p.group, p.bits
    n = int(math.prod(shape))
    C = w.shape[0]
    one_batch_axis = (p.codes.ndim - (len(shape) if p.mode == "shard"
                                      else 1)) == 1
    # the kernel route is f32-ONLY: for low-precision leaves, ``decode``
    # rounds every dequantized element to the leaf dtype before any
    # reduction — the gather path's per-element semantics. Accumulating
    # the raw f32 dequant instead would differ by up to a leaf-dtype ulp
    # per element (far beyond the documented f32 reduction-order
    # tolerance), so bf16 payloads keep the decode-then-tensordot path.
    route_ok = (fused is not False and n >= kernel_threshold
                and g % 128 == 0 and g >= 2 and one_batch_axis
                and jnp.dtype(p.dtype) == jnp.float32
                and p.scales.dtype == jnp.float32)
    if route_ok and fused is None:
        if isinstance(p.codes, jax.core.Tracer):
            # sharding unknowable at trace time: only safe on a
            # single-device process (mirrors _kernel_route)
            # repro: allow[RPL001] tracer fallback mirroring _kernel_route
            route_ok = jax.device_count() == 1
        else:
            sh = getattr(p.codes, "sharding", None)
            route_ok = (sh is None or sh.is_fully_replicated
                        or len(sh.device_set) == 1)
    if route_ok:
        codes = p.codes
        if codes.dtype == jnp.uint8:
            codes = unpack_nibbles(codes)
        if p.mode == "shard":
            D = shape[-1]
            c3 = codes.reshape(C, -1, D)
            s3 = p.scales.reshape(C, -1, D // g)
        else:
            # flat stream: group-wide rows, one scale per row (D == g)
            c3 = codes.reshape(C, -1, g)
            s3 = p.scales.reshape(C, -1, 1)
        out = kernel_ops.dequantize_reduce_grouped(c3, s3, w, bits=bits,
                                                   group=g)
        if p.mode == "flat":
            out = out.reshape(-1)[:n]
        return out.reshape(shape)
    return jnp.tensordot(w, decode_leaf(p), axes=1)


def decode_reduce_tree(payload, w,
                       kernel_threshold: int = KERNEL_DISPATCH_MIN,
                       fused: Optional[bool] = None):
    """``decode_reduce_leaf`` over a payload pytree: the mu-weighted
    partial aggregate of a stacked C-client payload, fusing dequantize
    into the accumulation leaf-wise (the ``uplink="reduce"`` server
    stage). ``w`` is the (C,) weight vector — fold the participation mask
    in by passing ``mu * mask`` (exact: the mask is 0.0/1.0). Partials
    come back in the accumulation dtype (see ``decode_reduce_leaf``);
    downcast once after the cross-device reduction."""
    return jax.tree.map(
        lambda p: decode_reduce_leaf(p, w, kernel_threshold=kernel_threshold,
                                     fused=fused),
        payload, is_leaf=_is_payload_leaf)


def block_quant(bits: int = 8, block: int = 256, dither: str = "uniform",
                shard_safe: bool = False,
                kernel_threshold: int = KERNEL_DISPATCH_MIN,
                compute: str = "f32", checksum: bool = False) -> Compressor:
    levels = 2.0 ** (bits - 1) - 1.0
    omega = block / (4.0 * levels * levels)

    def apply(key, s):
        return _tree_keyed_map(
            lambda k, x: quantize_leaf(k, x, bits=bits, block=block,
                                       dither=dither, shard_safe=shard_safe,
                                       kernel_threshold=kernel_threshold,
                                       compute=compute),
            key, s)

    def encode(key, s):
        return _tree_keyed_map(
            lambda k, x: encode_leaf(k, x, bits=bits, block=block,
                                     dither=dither, shard_safe=shard_safe,
                                     kernel_threshold=kernel_threshold,
                                     compute=compute, checksum=checksum),
            key, s)

    def decode_reduce(payload, w, fused=None):
        # honors THIS compressor's kernel_threshold (a closure argument,
        # not a Compressor field) — callers that disabled kernel dispatch
        # keep the bit-identical jnp reduce here too
        return decode_reduce_tree(payload, w,
                                  kernel_threshold=kernel_threshold,
                                  fused=fused)

    def payload(shape, itemsize):
        # EXACT wire bytes (mirrors encode_leaf): packed codes (1 byte per
        # coordinate, 0.5 when bits <= 4) + one scale per group (f32 under
        # the oracle semantics, input dtype under compute='native') + the
        # wire digest when checksum is on (billed honestly — integrity is
        # not free bytes); leaves encode() passes through raw (ndim-0
        # always; in shard-safe mode also g == 1 last dims) travel
        # uncompressed at their dtype and carry no digest
        n = float(math.prod(shape)) if shape else 1.0
        if not shape:
            return n * itemsize
        scale_sz = itemsize if compute == "native" and itemsize != 4.0 \
            else 4.0
        ck = float(CHECKSUM_BYTES) if (checksum and bits <= 8) else 0.0
        if not shard_safe:
            n_blocks = math.ceil(n / block)
            padded = n_blocks * block
            code_b = padded / 2.0 if (bits <= PACK_BITS and padded % 2 == 0) \
                else float(padded)
            return code_b + n_blocks * scale_sz + ck
        g = group_size(shape[-1], block)
        if g < 2:
            return n * itemsize
        code_b = n / 2.0 if bits <= PACK_BITS else n
        return code_b + (n / g) * scale_sz + ck

    tag = f"{dither},shard" if shard_safe else dither
    if compute == "native":
        tag += ",native"
    if checksum:
        tag += ",ck"
    return Compressor(apply=apply, omega=float(omega), bits=float(bits),
                      name=f"block_quant{bits}b{block}[{tag}]",
                      payload_fn=payload,
                      encode=encode if bits <= 8 else None,
                      decode=decode_tree if bits <= 8 else None,
                      decode_reduce=decode_reduce if bits <= 8 else None,
                      checksum=checksum and bits <= 8,
                      # the quantizer's tier-boundary reencode IS its
                      # encode: an edge partial is just another f32 tree,
                      # and encode stamps fresh per-tier digests
                      reencode=encode if bits <= 8 else None)


# ---------------------------------------------------------------------------
# Rand-k sparsification (Wangni et al. 2018): keep each coordinate with
# probability k/n, rescale by n/k. omega = n/k - 1.
# ---------------------------------------------------------------------------

def rand_k(fraction: float) -> Compressor:
    assert 0.0 < fraction <= 1.0
    omega = 1.0 / fraction - 1.0

    def leaf(key, x):
        mask = jax.random.bernoulli(key, fraction, x.shape)
        return jnp.where(mask, x / fraction, 0.0).astype(x.dtype)

    def apply(key, s):
        return _tree_keyed_map(leaf, key, s)

    def payload(shape, itemsize):
        # a sparse payload is (value, coordinate) pairs: each surviving
        # coordinate carries its value (itemsize bytes) PLUS its index —
        # ceil(log2 n) bits, clamped to >= 1 (an index field cannot be
        # narrower than a bit: the old model billed 0 index bits for
        # n == 1 leaves and called log2 on n == 0 for empty ones). The
        # pre-PR-3 model billed values only — a free-coordinates fiction
        # that understated e.g. a 1M-coord f32 leaf at fraction 0.1 by
        # ~38%.
        n = float(math.prod(shape)) if shape else 1.0
        if n == 0:
            return 0.0
        idx_bits = max(1, math.ceil(math.log2(n)))
        return n * fraction * (itemsize + idx_bits / 8.0)

    return Compressor(apply=apply, omega=float(omega), bits=32.0 * fraction,
                      name=f"rand_k{fraction:g}", payload_fn=payload)


# ---------------------------------------------------------------------------
# Lemma 1: partial participation composed on top of any compressor.
#   QuantTilde(s) = (U / p) * Quant(s),  U ~ Bernoulli(p)
#   => unbiased with omega_p = omega + (1 - p)(1 + omega)/p.
# ---------------------------------------------------------------------------

def with_participation(base: Compressor, p: float) -> Compressor:
    assert 0.0 < p <= 1.0
    omega_p = effective_omega(base.omega, p)

    def apply(key, s):
        k_u, k_q = jax.random.split(key)
        u = jax.random.bernoulli(k_u, p).astype(jnp.float32)
        q = base.apply(k_q, s)
        return jax.tree.map(lambda x: (u / p) * x, q)

    return Compressor(apply=apply, omega=float(omega_p), bits=base.bits * p,
                      name=f"{base.name}+pp{p:g}",
                      payload_fn=lambda shape, itemsize:
                          p * base._leaf_payload(shape, itemsize))


def effective_omega(omega: float, p: float) -> float:
    """omega_p = omega + (1 + omega)(1 - p)/p  (Lemma 1 / Theorem 1)."""
    return omega + (1.0 + omega) * (1.0 - p) / p
