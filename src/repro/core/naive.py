"""The paper's comparison baseline: *parameter-space* (Theta) aggregation.

"This naive algorithm exactly mirrors FedMM, except that the communications
and the server aggregation step occur in the parameter space and not in the
surrogate space" (Section 6). Concretely each active client computes its
local surrogate and minimizes it locally:

    theta_{t+1,i} = T( S_{t+1,i}(theta_t) )            (local MM step, eq. 21)
    Delta_i       = theta_{t+1,i} - theta_t - V_{t,i}
    q_i           = Quant(Delta_i)
    server:  theta_{t+1} = theta_t + gamma * (V_t + (1/p) sum mu_i q_i)

In the unified API this is not a fork but ONE FLAG:
``FederationSpec(aggregation="parameter")`` — this module is the thin shim
that keeps the historical entry points alive. Remark 1 shows the scheme's
fixed point is generally *not* a stationary point of the federated
objective under heterogeneity — reproduced in
tests/test_fedmm.py::test_remark1 and benchmarks/fig1_dictlearn.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .surrogate import Surrogate
from .fedmm import FedMMConfig
from .. import api


class NaiveState(NamedTuple):
    theta: object
    v: object
    v_i: object
    step: jnp.ndarray


def _to_driver(state: NaiveState) -> "api.DriverState":
    return api.DriverState(x=state.theta, v=state.v, v_i=state.v_i,
                           aux=(), opt=(), step=state.step)


def _from_driver(state: "api.DriverState") -> NaiveState:
    return NaiveState(theta=state.x, v=state.v, v_i=state.v_i,
                      step=state.step)


def init(sur: Surrogate, theta0, cfg: FedMMConfig) -> NaiveState:
    return _from_driver(api.init(api.as_problem(sur), theta0,
                                 cfg.as_spec("parameter")))


def step(sur: Surrogate, state: NaiveState, client_batches, gamma, key,
         cfg: FedMMConfig) -> tuple[NaiveState, dict]:
    dstate, metrics = api.step(api.as_problem(sur), cfg.as_spec("parameter"),
                               _to_driver(state), client_batches, gamma, key)
    return _from_driver(dstate), metrics


def _tbar_diag(sur: Surrogate, surrogate_diag_batches):
    """Tbar(theta) for the Section 6 cross-space diagnostic E^{s,p}
    (kept as a private alias; use ``api.mean_oracle_diag`` in new code)."""
    return api.mean_oracle_diag(api.as_problem(sur), surrogate_diag_batches)


def run(sur: Surrogate, theta0, client_batch_fn, gammas, key, cfg: FedMMConfig,
        n_rounds: int, eval_batch=None, surrogate_diag_batches=None):
    """Driver mirroring fedmm.run (one flag on the unified driver).
    ``surrogate_diag_batches`` (optional, (n, b, ...) pytree) enables the
    Section 6 cross-space diagnostic E^{s,p}:
    || Tbar(theta_{t+1}) - Tbar(theta_t) ||^2 / gamma^2."""
    diag = (("e_s_p", _tbar_diag(sur, surrogate_diag_batches))
            if surrogate_diag_batches is not None else None)
    state, hist = api.run(api.as_problem(sur), theta0, client_batch_fn,
                          gammas, spec=cfg.as_spec("parameter"), key=key,
                          n_rounds=n_rounds, eval_batch=eval_batch,
                          diag=diag)
    return _from_driver(state), api.history_list(hist)
