"""The paper's comparison baseline: *parameter-space* (Theta) aggregation.

"This naive algorithm exactly mirrors FedMM, except that the communications
and the server aggregation step occur in the parameter space and not in the
surrogate space" (Section 6). Concretely each active client computes its
local surrogate and minimizes it locally:

    theta_{t+1,i} = T( S_{t+1,i}(theta_t) )            (local MM step, eq. 21)
    Delta_i       = theta_{t+1,i} - theta_t - V_{t,i}
    q_i           = Quant(Delta_i)
    server:  theta_{t+1} = theta_t + gamma * (V_t + (1/p) sum mu_i q_i)

Remark 1 shows this scheme's fixed point is generally *not* a stationary
point of the federated objective under heterogeneity — reproduced in
tests/test_fedmm.py::test_remark1 and benchmarks/fig1_dictlearn.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .surrogate import (Surrogate, tree_add, tree_axpy, tree_scale, tree_sub,
                        tree_sq_norm)
from .fedmm import FedMMConfig, _mu


class NaiveState(NamedTuple):
    theta: object
    v: object
    v_i: object
    step: jnp.ndarray


def init(sur: Surrogate, theta0, cfg: FedMMConfig) -> NaiveState:
    v_i = jax.tree.map(lambda x: jnp.zeros((cfg.n_clients,) + x.shape, x.dtype), theta0)
    v = jax.tree.map(lambda x: jnp.zeros_like(x), theta0)
    return NaiveState(theta=theta0, v=v, v_i=v_i, step=jnp.asarray(0))


def step(sur: Surrogate, state: NaiveState, client_batches, gamma, key,
         cfg: FedMMConfig) -> tuple[NaiveState, dict]:
    n, p, alpha = cfg.n_clients, cfg.p, cfg.alpha
    mu = _mu(cfg)

    k_part, k_quant = jax.random.split(key)
    active = jax.random.bernoulli(k_part, p, (n,))
    quant_keys = jax.random.split(k_quant, n)

    def client_update(batch, v_i, qkey):
        s_i = sur.s_bar(batch, state.theta)
        theta_i = sur.T(s_i)                           # local minimization
        delta = tree_sub(tree_sub(theta_i, state.theta), v_i)
        return cfg.compressor.apply(qkey, delta)

    q = jax.vmap(client_update, in_axes=(0, 0, 0))(client_batches, state.v_i, quant_keys)
    mask = active.astype(jnp.float32)
    q = jax.tree.map(lambda x: x * mask.reshape((n,) + (1,) * (x.ndim - 1)), q)

    v_i_new = jax.tree.map(lambda v, dq: v + (alpha / p) * dq, state.v_i, q)
    agg = jax.tree.map(lambda x: jnp.tensordot(mu, x, axes=1), q)
    h_oracle = tree_add(state.v, tree_scale(agg, 1.0 / p))
    theta_new = tree_axpy(gamma, h_oracle, state.theta)
    v_new = tree_add(state.v, tree_scale(agg, alpha / p))

    metrics = {
        "e_p": tree_sq_norm(tree_sub(theta_new, state.theta)) / gamma ** 2,
        "n_active": jnp.sum(mask),
    }
    return NaiveState(theta=theta_new, v=v_new, v_i=v_i_new,
                      step=state.step + 1), metrics


def run(sur: Surrogate, theta0, client_batch_fn, gammas, key, cfg: FedMMConfig,
        n_rounds: int, eval_batch=None, surrogate_diag_batches=None):
    """Driver mirroring fedmm.run. ``surrogate_diag_batches`` (optional,
    (n, b, ...) pytree) enables the Section 6 cross-space diagnostic
    E^{s,p}: || Tbar(theta_{t+1}) - Tbar(theta_t) ||^2 / gamma^2 where
    Tbar(theta) = (1/n) sum_i Sbar_i(theta)."""
    state = init(sur, theta0, cfg)
    hist = []
    step_j = jax.jit(lambda st, cb, g, k: step(sur, st, cb, g, k, cfg))

    def tbar(theta):
        return jax.tree.map(
            lambda x: jnp.mean(x, axis=0),
            jax.vmap(lambda b: sur.s_bar(b, theta))(surrogate_diag_batches))

    s_prev = tbar(state.theta) if surrogate_diag_batches is not None else None
    for t in range(n_rounds):
        key, k_round, k_batch = jax.random.split(key, 3)
        gamma = float(gammas(t + 1)) if callable(gammas) else float(gammas[t])
        batches = client_batch_fn(t, k_batch)
        state, m = step_j(state, batches, gamma, k_round)
        m = {k: float(v) for k, v in m.items()}
        if s_prev is not None:
            s_new = tbar(state.theta)
            m["e_s_p"] = float(tree_sq_norm(tree_sub(s_new, s_prev))) / gamma ** 2
            s_prev = s_new
        if sur.loss is not None and eval_batch is not None:
            m["loss"] = float(sur.loss(eval_batch, state.theta))
        hist.append(m)
    return state, hist
