"""Core library: the paper's primary contribution.

MM-1/MM-2 surrogate framework, SA-SSMM (Algorithm 1), FedMM (Algorithm 2)
with control variates / partial participation / compression / projection,
the naive Theta-aggregation baseline, and FedMM-OT (Algorithm 3).

The algorithm run loops are unified behind ``repro.api`` (one MMProblem
protocol + FederationSpec + scan-jitted driver); the ``sassmm``/``fedmm``/
``naive``/``fedmm_ot`` modules here are compatibility shims over it.
"""
from . import (compression, fedmm, fedmm_ot, jensen, naive, prox, quadratic,  # noqa: F401
               sassmm, surrogate, variational)
from .surrogate import Surrogate  # noqa: F401
from .fedmm import FedMMConfig, FedMMState  # noqa: F401
