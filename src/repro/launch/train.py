"""End-to-end FedMM LM training driver (deliverable b).

Trains an assigned architecture (reduced or full, per --preset) with the
FedMM federated trainer on synthetic heterogeneous token data. On this CPU
container use --preset smoke (reduced configs) or --preset 100m; on a real
slice drop --preset to train the full config on the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch phi3-medium-14b \
      --preset 100m --steps 300 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

import repro.configs as C
from repro.data.synthetic import token_stream
from repro.fed import trainer as FT
from repro.models.model import build_model
from repro.checkpoint import checkpoint as ckpt


def preset_config(cfg, preset: str):
    if preset == "full":
        return cfg
    if preset == "smoke":
        return cfg.reduced()
    if preset == "100m":
        # ~100M-parameter variant of the same family
        return dataclasses.replace(
            cfg.reduced(), n_layers=max(4, cfg.reduced().n_layers),
            d_model=512, d_ff=1536,
            n_heads=8 if cfg.n_heads else 0,
            n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
            head_dim=64 if cfg.head_dim else 0,
            vocab=min(cfg.vocab, 32768), rwkv_head_dim=64, dtype="float32")
    raise ValueError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=C.ARCH_IDS)
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8, help="global batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--quant-bits", type=int, default=8)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = preset_config(C.get(args.arch), args.preset)
    model = build_model(cfg)
    fcfg = FT.FedLMConfig(
        n_clients=args.clients, rho=args.rho, p=args.participation,
        alpha=args.alpha, quant_bits=args.quant_bits, client_mode="logical")

    key = jax.random.PRNGKey(0)
    state = FT.init_state(model, key, fcfg)
    n_params = FT.param_count(model)
    print(f"arch={cfg.name} preset={args.preset} params={n_params/1e6:.1f}M "
          f"clients={args.clients} p={args.participation} "
          f"quant={args.quant_bits}b")

    step_fn = jax.jit(FT.make_train_step(model, fcfg))
    b_local = args.batch // args.clients

    # heterogeneous client token streams (non-IID unigram skew)
    def sample_batch(k):
        k1, k2 = jax.random.split(k)
        toks = jax.vmap(
            lambda kk: token_stream(kk, b_local, args.seq + 1, cfg.vocab)
        )(jax.random.split(k1, args.clients))          # (n, b, S+1)
        batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                k2, (args.clients, b_local, cfg.n_frontend_tokens,
                     cfg.d_model)) * 0.02
        elif cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                k2, (args.clients, b_local, cfg.n_frontend_tokens,
                     cfg.d_model)) * 0.02
        return batch

    t0 = time.time()
    for t in range(args.steps):
        key, kb, ks = jax.random.split(key, 3)
        gamma = args.gamma / (1.0 + t) ** 0.5
        state, m = step_fn(state, sample_batch(kb), ks, gamma)
        if t % args.log_every == 0 or t == args.steps - 1:
            print(f"step {t:5d}  loss={float(m['loss']):.4f} "
                  f"e_s={float(m['e_s']):.3e}  active={int(m['n_active'])} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    if args.checkpoint:
        ckpt.save(args.checkpoint, state.s_hat)
        print(f"saved mirror parameter to {args.checkpoint}")


if __name__ == "__main__":
    main()
