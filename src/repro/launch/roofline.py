"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (peak_FLOP/s)          [cost_analysis is
    memory     = HLO_bytes / HBM_bw                   *per-device* on the
    collective = collective_bytes / ICI_bw            partitioned module]

collective_bytes is not in cost_analysis: we parse the partitioned HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (an upper-ish proxy for
wire bytes per device; ICI transfers the full result for gathers and the
operand for reductions — we report the max of operand/result per op).
"""
from __future__ import annotations

import re
from typing import Dict

from .mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# Trip-count-aware HLO accounting.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE — with scan-over-layers
# (the whole point of compact lowering) that undercounts flops/bytes by the
# layer count. The compiled HLO annotates loops with
# backend_config={"known_trip_count":{"n":...}}, so we walk the call graph
# (ENTRY -> while bodies x trip count -> fusions/calls) and weight each
# computation by its execution multiplicity.
# ---------------------------------------------------------------------------

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_REF = re.compile(r"(?:condition|body|calls|to_apply|branch_computations)="
                  r"\{?%?([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIVIAL = ("tuple(", "get-tuple-element(", "parameter(", "constant(",
            "bitcast(", "after-all(", "partition-id(", "iota(")


def _first_shape_elems(text):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, 0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return dims, n * _DTYPE_BYTES[m.group(1)]


def _parse_computations(hlo_text: str):
    comps, cur, name = {}, None, None
    for line in hlo_text.splitlines():
        s = line.rstrip()
        m = _COMP_HEADER.match(s.strip())
        if m and s.strip().endswith("{"):
            name = m.group(2)
            cur = []
            comps[name] = {"instrs": cur, "entry": bool(m.group(1))}
            continue
        if s.strip() == "}":
            name, cur = None, None
            continue
        if cur is not None:
            mi = _INSTR.match(s)
            if mi:
                cur.append((mi.group(1), mi.group(2)))
    return comps


def _call_edges(rhs):
    """Yield (callee_name, weight) for one instruction's rhs text."""
    mt = _TRIP.search(rhs)
    trip = float(mt.group(1)) if mt else 1.0
    for kw, factor in (("body", trip), ("condition", trip), ("calls", 1.0),
                       ("to_apply", 1.0), ("branch_computations", 1.0)):
        m = re.search(kw + r"=(\{[^}]*\}|%[\w.\-]+)", rhs)
        if m:
            for callee in re.findall(r"%([\w.\-]+)", m.group(1)):
                yield callee, factor


def _multipliers(comps):
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    if entry is None:
        return {n: 1.0 for n in comps}
    edges = {n: [] for n in comps}       # caller -> [(callee, weight)]
    for name, comp in comps.items():
        for _, rhs in comp["instrs"]:
            for callee, w in _call_edges(rhs):
                if callee in comps:
                    edges[name].append((callee, w))

    # topological order via DFS from entry (the computation graph is a DAG)
    topo, seen = [], set()

    def dfs(n):
        if n in seen:
            return
        seen.add(n)
        for c, _ in edges[n]:
            dfs(c)
        topo.append(n)

    import sys
    old = sys.getrecursionlimit()
    sys.setrecursionlimit(10000)
    try:
        dfs(entry)
    finally:
        sys.setrecursionlimit(old)

    mult = {n: 0.0 for n in comps}
    mult[entry] = 1.0
    for n in reversed(topo):             # callers before callees
        for c, w in edges[n]:
            mult[c] += mult[n] * w
    return mult


def _dot_flops(rhs, symbols):
    """2 * result_elems * prod(contracting dims of lhs)."""
    dims, rbytes = _first_shape_elems(rhs)
    if dims is None:
        return 0.0
    relems = 1
    for d in dims:
        relems *= d
    mC = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    inside = rhs.split("dot(", 1)[1]
    ops = _OPERAND.findall(inside.split(")", 1)[0])
    lhs_shape = symbols.get(ops[0]) if ops else None
    k = 1
    if mC and lhs_shape:
        for idx in (int(x) for x in mC.group(1).split(",") if x):
            if idx < len(lhs_shape):
                k *= lhs_shape[idx]
    return 2.0 * relems * k


def hlo_accounting(hlo_text: str) -> Dict:
    """Trip-count-weighted per-device accounting from the partitioned HLO:
    dot flops, collective bytes (max of operand/result shapes per op), and a
    fusion-boundary HBM-traffic proxy (operands+result bytes of every
    non-trivial top-level instruction)."""
    comps = _parse_computations(hlo_text)
    mult = _multipliers(comps)
    # symbol tables: instruction name -> (result dims, result bytes)
    symbols, sym_bytes = {}, {}
    for cname, comp in comps.items():
        for iname, rhs in comp["instrs"]:
            dims, b = _first_shape_elems(rhs)
            symbols[iname] = dims
            sym_bytes[iname] = b
    flops = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    traffic = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        if m == 0.0:
            continue
        for iname, rhs in comp["instrs"]:
            if " dot(" in rhs or rhs.split(" ", 2)[-1].startswith("dot("):
                flops += m * _dot_flops(rhs, symbols)
            kind = None
            for k in _COLLECTIVES:
                if re.search(rf"\b{k}(-start)?\(", rhs):
                    kind = k
                    break
            if kind:
                sizes = [b for _, b in [_first_shape_elems(rhs)] if b]
                inside = rhs.split("(", 1)
                if len(inside) == 2:
                    for op in _OPERAND.findall(inside[1].split(")", 1)[0]):
                        if sym_bytes.get(op):
                            sizes.append(sym_bytes[op])
                if sizes:
                    coll[kind] += m * max(sizes)
                    counts[kind] += 1
            if not any(t in rhs for t in _TRIVIAL):
                # fusion-boundary traffic: result + operand bytes, with
                # in-place/windowed ops special-cased (a dynamic-update-slice
                # writes one token into a TB-scale cache: on TPU it is an
                # aliased in-place write, not a full-buffer copy).
                _, rb = _first_shape_elems(rhs)
                inside = rhs.split("(", 1)
                ops = (_OPERAND.findall(inside[1].split(")", 1)[0])
                       if len(inside) == 2 else [])
                if "dynamic-update-slice(" in rhs:
                    upd = sym_bytes.get(ops[1], 0) if len(ops) > 1 else 0
                    traffic += m * 2 * upd
                elif "dynamic-slice(" in rhs:
                    traffic += m * 2 * rb
                elif " copy(" in rhs or rhs.startswith("copy("):
                    pass  # layout copies are elided / aliased on TPU
                elif "gather(" in rhs and "all-gather(" not in rhs:
                    traffic += m * 2 * rb
                else:
                    traffic += m * (rb + sum(sym_bytes.get(o, 0)
                                             for o in ops))
    total_coll = sum(coll.values())
    return {"flops": flops,
            "collective_bytes": total_coll,
            "by_kind": {k: v for k, v in coll.items() if v},
            "counts": {k: v for k, v in counts.items() if v},
            "traffic_bytes": traffic}


def _shape_bytes(m):
    dtype, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict:
    """Sum bytes moved by collectives in a partitioned HLO module.
    For each collective instruction line, takes max(result, operands) shape
    bytes (all shapes on the line) as the per-device wire-bytes proxy."""
    by_kind = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        kind = None
        rhs = stripped.split("=", 1)[1]
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done" in rhs:
            continue  # counted at -start
        sizes = [_shape_bytes(m) for m in _SHAPE_RE.finditer(stripped)]
        if not sizes:
            continue
        by_kind[kind] += float(max(sizes))
        counts[kind] += 1
    total = sum(by_kind.values())
    return {"total_bytes": total,
            "by_kind": {k: v for k, v in by_kind.items() if v},
            "counts": {k: v for k, v in counts.items() if v}}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, n_chips: int) -> Dict:
    """cost_analysis numbers are already per-device on the partitioned
    module, so the chip count enters only through the partitioning itself."""
    compute_s = flops_per_device / PEAK_FLOPS_BF16
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    return {**terms, "dominant": dominant.replace("_s", ""),
            "n_chips": n_chips}


def analytic_bytes(cfg, shape, n_params: int, n_clients: int = 1,
                   client_mode: str = "physical", dp: int = 16,
                   tp: int = 16, n_chips: int = 256) -> float:
    """First-order per-device HBM traffic model (the roofline memory term).

    The HLO fusion-boundary proxy overcharges loop carries (VMEM-resident on
    TPU: the WKV/Mamba state, flash-attention online-softmax state), so the
    memory term uses structural napkin math instead:

      weights/pass/device = P_bytes / TP   (2-D sharded, fsdp-gathered slab)
      train  = 3 passes x weights x (n sequential clients if logical)
               + FedMM state R/W + activation traffic (c ~= 30 tensor
                 touches/layer incl. backward)
      prefill = weights + activations (c ~= 12) + cache write
      decode  = weights (all experts touched at B*topk >= E) + cache read
    """
    P_b = n_params * 2.0
    d, L = cfg.d_model, cfg.n_layers
    GB, S = shape.global_batch, shape.seq_len
    w_pass = P_b / tp

    att_layers = L
    if cfg.attn_every:
        att_layers = L // cfg.attn_every
    win = cfg.window or S
    cache_b = 0.0
    if cfg.n_heads:
        glob = L // cfg.global_every if cfg.global_every else att_layers
        loc = (L - glob) if cfg.global_every else 0
        kv_bytes = 1 if cfg.kv_dtype == "int8" else 2
        cache_b = (glob * S + loc * min(win, S)) * GB \
            * cfg.n_kv_heads * cfg.hd * 2 * kv_bytes

    if shape.kind == "train":
        tokens_dev = GB * S / dp
        acts = tokens_dev * d * 2 * L * 30
        if client_mode == "logical":
            w = 3 * w_pass * n_clients
            fed = (4 + 3 * n_clients) * P_b / n_chips
        else:
            w = 3 * w_pass
            fed = 8 * P_b / tp
        return w + fed + acts
    if shape.kind == "prefill":
        tokens_dev = GB * S / dp
        return w_pass + tokens_dev * d * 2 * L * 12 + cache_b / n_chips
    # decode: one token per sequence
    return P_b / tp + cache_b / n_chips + GB / dp * d * 2 * L * 12


def model_flops_estimate(cfg, shape, n_params: int) -> float:
    """MODEL_FLOPS = 6 N D (dense) or 6 N_active D (MoE) for training;
    2 N D for a forward-only step (prefill), 2 N_active per decoded token."""
    if cfg.n_experts:
        # active params: replace the E-expert FFN stack by top_k experts
        shapes_factor = cfg.top_k / cfg.n_experts
        # rough split: expert params dominate MoE configs
        expert_params = (cfg.n_layers // cfg.moe_every) * cfg.n_experts \
            * 3 * cfg.d_model * cfg.d_ff
        n_active = n_params - expert_params * (1.0 - shapes_factor)
    else:
        n_active = n_params
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/sequence
