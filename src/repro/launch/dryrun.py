import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) combination, lower + compile
the real step function — the FedMM train step for train_4k, serve prefill /
decode for the inference shapes — against the production mesh with
ShapeDtypeStruct stand-ins (no allocation), then record:

  * compiled.memory_analysis()  (per-device bytes -> proves it fits)
  * compiled.cost_analysis()    (FLOPs / bytes for the roofline)
  * collective bytes parsed from the partitioned HLO (roofline 3rd term)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_all.json
"""
import argparse
import dataclasses
import json
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.configs.base import INPUT_SHAPES
from repro.fed import trainer as FT
from repro.launch import mesh as M
from repro.launch.roofline import (analytic_bytes, hlo_accounting,
                                   roofline_terms, model_flops_estimate)
from repro.models import sharding as shd
from repro.models.model import build_model


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda l, s: _sds(l.shape, l.dtype, mesh, s), shapes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(cfg, shape, mesh, fed_cfg=None, n_clients=None):
    """ShapeDtypeStruct stand-ins for every model input of this shape.
    Training inputs carry the leading client dim (FedMM batch contract)."""
    multi = "pod" in mesh.axis_names
    batch_axes = M.client_axes(multi)
    bs = int(np.prod([mesh.shape[a] for a in batch_axes]))
    GB, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        n = n_clients
        b_local = GB // n
        bspec = FT.batch_spec(fed_cfg, batch_axes)
        out = {
            "tokens": _sds((n, b_local, S), jnp.int32, mesh, bspec),
            "labels": _sds((n, b_local, S), jnp.int32, mesh, bspec),
        }
        fs = P(*(list(bspec) + [None]))
        if cfg.family == "vlm":
            out["patches"] = _sds((n, b_local, cfg.n_frontend_tokens,
                                   cfg.d_model), jnp.float32, mesh, fs)
        elif cfg.family == "audio":
            out["frames"] = _sds((n, b_local, cfg.n_frontend_tokens,
                                  cfg.d_model), jnp.float32, mesh, fs)
        return out

    bspec = P(batch_axes if GB % bs == 0 else None, None)
    out = {"tokens": _sds((GB, S), jnp.int32, mesh, bspec),
           "labels": _sds((GB, S), jnp.int32, mesh, bspec)}
    if cfg.family == "vlm":
        out["patches"] = _sds((GB, cfg.n_frontend_tokens, cfg.d_model),
                              jnp.float32, mesh, P(bspec[0], None, None))
    elif cfg.family == "audio":
        out["frames"] = _sds((GB, cfg.n_frontend_tokens, cfg.d_model),
                             jnp.float32, mesh, P(bspec[0], None, None))
    return out


def compile_one(arch_id: str, shape_name: str, multi_pod: bool,
                overrides=None, variant=None):
    """Lower + compile one combination; returns a metrics dict.

    ``variant`` (perf-iteration levers, §Perf):
      kv_dtype="int8"        quantized KV cache (decode shapes)
      attn_mode="replicated" attention weights replicated over 'model' (train)
      use_cv=False           drop control variates (alpha=0 regime)
      quant_bits=<n>         FedMM uplink quantization width (0 = off)
      n_clients=<n>          override the client layout
    """
    variant = variant or {}
    cfg = C.get(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if "kv_dtype" in variant:
        cfg = dataclasses.replace(cfg, kv_dtype=variant["kv_dtype"])
    if "moe_group" in variant:
        cfg = dataclasses.replace(cfg, moe_group=variant["moe_group"])
    shape = INPUT_SHAPES[shape_name]

    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return {"arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped",
                "reason": "full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §4)"}

    mesh = M.make_production_mesh(multi_pod=multi_pod)
    multi = multi_pod
    batch_axes = M.client_axes(multi)
    fsdp_size = int(np.prod([mesh.shape[a] for a in batch_axes]))
    n_chips = int(np.prod(list(mesh.shape.values())))
    model = build_model(cfg)
    shd.install_rules(M.axis_rules(multi))

    try:
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n_params = sum(int(np.prod(l.shape)) if l.shape else 1
                       for l in jax.tree.leaves(params_shapes))

        if shape.kind == "train":
            n_clients, mode = FT.choose_client_layout(n_params, multi)
            n_clients = variant.get("n_clients", n_clients)
            fed_cfg = FT.FedLMConfig(
                n_clients=n_clients, client_mode=mode,
                use_cv=variant.get("use_cv", True),
                alpha=0.0 if not variant.get("use_cv", True) else 0.1,
                quant_bits=variant.get("quant_bits", 8),
                attn_mode=variant.get("attn_mode", "sharded"),
                mlp_mode=variant.get("mlp_mode", "generic"))
            sspec, vspec, vispec = FT.state_specs(
                params_shapes, fed_cfg, fsdp=batch_axes, fsdp_size=fsdp_size)
            use_cv = fed_cfg.use_cv
            state_sds = FT.FedLMState(
                s_hat=_tree_sds(params_shapes, sspec, mesh),
                v=_tree_sds(params_shapes, vspec, mesh) if use_cv else {},
                v_i=jax.tree.map(
                    lambda l, s: _sds((n_clients,) + l.shape, l.dtype, mesh, s),
                    params_shapes, vispec,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
                if use_cv else {},
                step=_sds((), jnp.int32, mesh, P()))
            batch_sds = input_specs(cfg, shape, mesh, fed_cfg, n_clients)
            key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
            step_fn = FT.make_train_step(model, fed_cfg)
            fn = lambda st, b, k: step_fn(st, b, k, 0.1)
            donate = (0,)   # state buffers alias in place
            args = (state_sds, batch_sds, key_sds)
            extra = {"n_clients": n_clients, "client_mode": mode}
        elif shape.kind == "prefill":
            pspec = shd.param_specs(params_shapes, fsdp=batch_axes,
                                    fsdp_size=fsdp_size,
                                    attn_mode=variant.get("attn_mode", "sharded"),
                                    mlp_mode=variant.get("mlp_mode", "generic"))
            params_sds = _tree_sds(params_shapes, pspec, mesh)
            batch_sds = input_specs(cfg, shape, mesh)
            fn = lambda p, b: model.prefill(p, b)
            donate = ()
            args = (params_sds, batch_sds)
            extra = {}
        else:  # decode
            # fsdp_off (§Perf): TP-resident weights for serving — no
            # per-token FSDP weight gathers, at P_bytes/16 per device.
            p_fsdp = () if variant.get("fsdp_off") else batch_axes
            p_fsdp_size = 10**9 if variant.get("fsdp_off") else fsdp_size
            pspec = shd.param_specs(params_shapes, fsdp=p_fsdp,
                                    fsdp_size=p_fsdp_size,
                                    attn_mode=variant.get("attn_mode", "sharded"),
                                    mlp_mode=variant.get("mlp_mode", "generic"))
            params_sds = _tree_sds(params_shapes, pspec, mesh)
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspec = shd.cache_specs(cache_shapes, batch_axes,
                                    batch_size=fsdp_size)
            cache_sds = _tree_sds(cache_shapes, cspec, mesh)
            GB = shape.global_batch
            tok_spec = P(batch_axes if GB % fsdp_size == 0 else None, None)
            tok_sds = _sds((GB, 1), jnp.int32, mesh, tok_spec)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            fn = lambda p, c, t, pos: model.decode(p, c, t, pos)
            donate = (1,)   # cache updates in place
            args = (params_sds, cache_sds, tok_sds, pos_sds)
            extra = {}

        # jax.set_mesh is the newer-jax spelling; on older releases the Mesh
        # context manager provides the same ambient mesh (shardings here are
        # explicit NamedShardings, so the context only scopes the lowering).
        set_mesh = getattr(jax, "set_mesh", None)
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, (list, tuple)):   # older jax: one dict/program
                cost = cost[0] if cost else {}
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        acct = hlo_accounting(hlo)
        flops_dev = acct["flops"]                  # trip-count-weighted dots
        bytes_dev = analytic_bytes(               # structural HBM model
            cfg, shape, n_params,
            n_clients=extra.get("n_clients", 1),
            client_mode=extra.get("client_mode", "physical"),
            dp=fsdp_size, tp=mesh.shape["model"], n_chips=n_chips)
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        } if mem is not None else {}
        terms = roofline_terms(flops_dev, bytes_dev, acct["collective_bytes"],
                               n_chips=n_chips)
        mf = model_flops_estimate(cfg, shape, n_params)
        result = {
            "arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
            "status": "ok", "n_params": n_params, "n_chips": n_chips,
            "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
            "hlo_traffic_proxy_bytes": acct["traffic_bytes"],
            "collective_bytes_per_device": acct["collective_bytes"],
            "collectives": acct["by_kind"],
            "collective_counts": acct["counts"],
            "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                                  "bytes": float(cost.get("bytes accessed", 0.0))},
            "memory": mem_stats, "roofline": terms,
            "model_flops": mf,
            "useful_flops_ratio": (mf / (flops_dev * n_chips)
                                   if flops_dev else None),
            **extra,
        }
        return result
    except (ValueError, TypeError, NotImplementedError, RuntimeError) as e:
        # compile/lowering failures only (shape errors, unsupported ops,
        # XlaRuntimeError/Mosaic are RuntimeError subclasses): those are a
        # sweep RESULT. Anything else — KeyboardInterrupt, OOM kills,
        # our own bugs (AttributeError/KeyError/...) — propagates
        return {"arch": arch_id, "shape": shape_name, "multi_pod": multi_pod,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    finally:
        shd.install_rules(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) on this mesh")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch subset (with --all)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = args.archs.split(",") if args.archs else C.ARCH_IDS
    combos = ([(a, s) for a in archs for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    results = []
    for arch, shape in combos:
        r = compile_one(arch, shape, args.multi_pod)
        results.append(r)
        status = r["status"]
        brief = (f"{arch:28s} {shape:12s} pod={2 if args.multi_pod else 1} "
                 f"{status}")
        if status == "ok":
            t = r["roofline"]
            brief += (f"  mem={r['memory'].get('temp_bytes', 0)/2**30:.2f}GiB "
                      f"compute={t['compute_s']:.4f}s "
                      f"hbm={t['memory_s']:.4f}s ici={t['collective_s']:.4f}s "
                      f"-> {t['dominant']}")
        elif status == "error":
            brief += "  " + r["error"][:120]
        print(brief, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
