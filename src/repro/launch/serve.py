"""Batched serving driver: prefill a batch of prompts, then greedy-decode
with the ring-buffer KV cache (int8-quantized with --int8-kv).

On this CPU container use the reduced configs; on a real slice the same
code path serves the full configs with the decode sharding of DESIGN.md §5
(batch over 'data', cache sequence over 'model').

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
      --batch 4 --prompt-len 32 --new-tokens 16 [--int8-kv]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models.model import build_model, make_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b", choices=C.ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = C.get(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    batch = make_batch(key, cfg, args.batch, args.prompt_len)
    cache_len = args.prompt_len + args.new_tokens
    n_prefix = cfg.n_frontend_tokens if cfg.family == "vlm" else 0

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=n_prefix + cache_len))
    decode = jax.jit(model.decode)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.asarray(n_prefix + args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} kv={cfg.kv_dtype or cfg.dtype} batch={args.batch}")
    print(f"prefill {args.prompt_len} tok: {t_prefill*1e3:.1f} ms "
          f"| decode {args.new_tokens-1} steps: "
          f"{t_decode/(args.new_tokens-1)*1e3:.1f} ms/token")
    print("generated token ids (seq 0):", gen[0].tolist())
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))


if __name__ == "__main__":
    main()
