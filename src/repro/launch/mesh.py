"""Production mesh + logical-axis rule installation.

The target is a TPU v5e pod-slice: 256 chips per pod arranged (16, 16) as
('data', 'model'); the 2-pod production job is (2, 16, 16) with the leading
'pod' axis (DESIGN.md §3: pods are the federated silos). Importing this
module never touches JAX device state — construction happens inside
``make_production_mesh()``.
"""
from __future__ import annotations

import numpy as np

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    # jax.sharding.AxisType only exists on newer jax; Auto is the default
    # axis type there, so omitting it is equivalent on older releases.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.Mesh(devices, axes)
    return jax.sharding.Mesh(
        devices, axes, axis_types=(axis_type.Auto,) * len(axes))


def client_axes(multi_pod: bool):
    """The federated-client mesh axes (batch / silo axes)."""
    return ("pod", "data") if multi_pod else ("data",)


def cohort_capacity(mesh, client_axis: str = "clients",
                    per_device: int = 1) -> int:
    """The cohort size a ``repro.sched.CohortScheduler`` should stream
    through ``mesh``: one client slot per device on the client axis times
    ``per_device`` (raise it when a single client's oracle underfills a
    device). This is the C that makes the shard_mapped client stage run
    with zero idle devices and device memory independent of the population
    size — the scheduler pads the last ragged cohort up to it."""
    if client_axis not in mesh.shape:
        raise ValueError(f"client_axis={client_axis!r} not an axis of "
                         f"the mesh (axes: {tuple(mesh.shape)})")
    if per_device < 1:
        raise ValueError(f"per_device must be >= 1, got {per_device}")
    return int(mesh.shape[client_axis]) * per_device


def axis_rules(multi_pod: bool) -> dict:
    """Logical-axis -> mesh-axis rules installed for activations."""
    fsdp = client_axes(multi_pod)
    return {
        "batch": fsdp,
        "clients": fsdp,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "cache_seq": "model",
    }
