"""Production mesh + logical-axis rule installation.

The target is a TPU v5e pod-slice: 256 chips per pod arranged (16, 16) as
('data', 'model'); the 2-pod production job is (2, 16, 16) with the leading
'pod' axis (DESIGN.md §3: pods are the federated silos). Importing this
module never touches JAX device state — construction happens inside
``make_production_mesh()``.
"""
from __future__ import annotations

import numpy as np

import jax

# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    # jax.sharding.AxisType only exists on newer jax; Auto is the default
    # axis type there, so omitting it is equivalent on older releases.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.Mesh(devices, axes)
    return jax.sharding.Mesh(
        devices, axes, axis_types=(axis_type.Auto,) * len(axes))


def client_axes(multi_pod: bool):
    """The federated-client mesh axes (batch / silo axes)."""
    return ("pod", "data") if multi_pod else ("data",)


def make_edge_mesh(n_edges: int, clients_per_edge: int = None, *,
                   edge_axis: str = "edge", client_axis: str = "client",
                   devices=None):
    """A 2-D ``(edge, client)`` mesh for two-tier aggregation.

    Device (e, c) hosts client block ``e * clients_per_edge + c``, so each
    edge owns a CONTIGUOUS block of the stacked client axis — the same
    edge-major order ``Topology.edge_ids`` assigns, which is what lets a
    within-edge psum over ``client_axis`` and a cross-edge psum over
    ``edge_axis`` reproduce the flat reduction (up to association).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if n_edges < 1:
        raise ValueError(f"n_edges must be >= 1, got {n_edges}")
    if clients_per_edge is None:
        if len(devices) % n_edges:
            raise ValueError(
                f"{len(devices)} devices do not split over n_edges="
                f"{n_edges}; pass clients_per_edge explicitly")
        clients_per_edge = len(devices) // n_edges
    if clients_per_edge < 1:
        raise ValueError(
            f"clients_per_edge must be >= 1, got {clients_per_edge}")
    if edge_axis == client_axis:
        raise ValueError(
            f"edge_axis and client_axis must differ, both {edge_axis!r}")
    n = n_edges * clients_per_edge
    if len(devices) < n:
        raise ValueError(
            f"two-tier mesh ({n_edges} edges x {clients_per_edge} clients) "
            f"needs {n} devices, have {len(devices)}")
    grid = np.asarray(devices[:n]).reshape(n_edges, clients_per_edge)
    axes = (edge_axis, client_axis)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.sharding.Mesh(grid, axes)
    return jax.sharding.Mesh(
        grid, axes, axis_types=(axis_type.Auto,) * len(axes))


def cohort_capacity(mesh, client_axis="clients", per_device: int = 1) -> int:
    """The cohort size a ``repro.sched.CohortScheduler`` should stream
    through ``mesh``: one client slot per device on the client axis times
    ``per_device`` (raise it when a single client's oracle underfills a
    device). This is the C that makes the shard_mapped client stage run
    with zero idle devices and device memory independent of the population
    size — the scheduler pads the last ragged cohort up to it.

    ``client_axis`` may be a tuple of axis names — e.g. the two-tier
    ``("edge", "client")`` layout — in which case the capacity is the
    product of the named axis sizes times ``per_device``.
    """
    axes = (client_axis,) if isinstance(client_axis, str) \
        else tuple(client_axis)
    if not axes:
        raise ValueError("client_axis must name at least one mesh axis")
    for ax in axes:
        if ax not in mesh.shape:
            raise ValueError(f"client_axis={ax!r} not an axis of "
                             f"the mesh (axes: {tuple(mesh.shape)})")
    if per_device < 1:
        raise ValueError(f"per_device must be >= 1, got {per_device}")
    cap = per_device
    for ax in axes:
        cap *= int(mesh.shape[ax])
    return cap


def axis_rules(multi_pod: bool) -> dict:
    """Logical-axis -> mesh-axis rules installed for activations."""
    fsdp = client_axes(multi_pod)
    return {
        "batch": fsdp,
        "clients": fsdp,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "cache_seq": "model",
    }
